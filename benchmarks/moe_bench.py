"""EP-vs-gathered MoE benchmark: the acceptance trajectory for treating
expert parallelism as a schedulable tick-engine resource.

Three row families, all on the qwen2-moe reduced config (8 experts
top-2, 3 layers — small enough for fake CPU devices, structured enough
that the a2a cost terms are nonzero):

* ``moe/train_{mode}`` — measured train-step wall time per expert
  placement, with the simulated a2a share from the plan analysis in the
  derived column (what ``moe_mode="auto"`` ranks on);
* ``moe/auto_resolved`` — which placement the a2a-aware cost model
  picked and both simulated scores (the §4 search run once per mode);
* ``moe/serve_capacity_*`` — engine-level capacity-aware admission: a
  tight skew bound serves the same workload with deferred admissions,
  token-identically, trading occupancy for zero projected drops.

Run standalone:
  SPMD_DEVICES=8 PYTHONPATH=src:. python -m benchmarks.moe_bench
"""

from __future__ import annotations

import numpy as np

from benchmarks import timing

ARCH = "qwen2-moe-a2.7b"


def _train_row(mode: str, *, data: int = 2, seq: int = 32,
               microbatches: int = 2):
    import jax

    from repro.api import session

    sess = session(ARCH, mode="train", data=data, seq_len=seq,
                   moe_mode=mode,
                   overrides=dict(microbatches=microbatches))
    sched = sess.describe()["schedule"]
    coll = sched.get("collectives", {})
    params = sess.init_params(jax.random.PRNGKey(0))
    batch = sess.stream(seed=0).batch(0)
    step = sess.train_step_fn()
    us = timing.measure_us(lambda: step(params, batch), warmup=1, iters=3)
    derived = (f"moe_mode={mode};makespan={sched['makespan']:.3e};"
               f"a2a_total={coll.get('a2a_total_s', 0.0):.3e};"
               f"t_a2a={coll.get('a2a_t_event_s', 0.0):.3e}")
    return (f"moe/train_{mode}", us, derived), us


def _auto_row(*, data: int = 2, seq: int = 32, microbatches: int = 2):
    from repro.api import session

    sess = session(ARCH, mode="train", data=data, seq_len=seq,
                   schedule="auto", moe_mode="auto",
                   overrides=dict(microbatches=microbatches))
    d = sess.describe()["schedule"]
    auto = d.get("moe_mode_auto", {})
    return ("moe/auto_resolved", 0.0,
            f"resolved={auto.get('resolved')};scores="
            + ",".join(f"{m}:{s:.3e}"
                       for m, s in sorted(auto.get("scores", {}).items())))


def _serve_capacity_rows(*, data: int = 2, max_slots: int = 4):
    import time

    import jax

    from repro.api import session
    from repro.serving import MoECapacity, SchedulerPolicy

    sess = session(ARCH, mode="serve", data=data, max_slots=max_slots,
                   max_seq=24, moe_mode="ep",
                   overrides=dict(microbatches=2, moe_stats=True))
    params = sess.init_params(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, sess.cfg.vocab, size=n).astype(np.int32)
               for n in (3, 8, 5, 6, 4, 7)]

    def run(policy):
        eng = sess.serve_engine(params, policy=policy)
        hs = [eng.submit(p, max_gen=4) for p in prompts]
        t0 = time.perf_counter()
        eng.run_until_idle()
        dt = time.perf_counter() - t0
        toks = [h.result(timeout=10) for h in hs]
        return toks, eng.stats, dt

    run(None)                                 # warmup: pay jit compiles
    toks_open, st_open, dt_open = run(None)   # default cfg-derived bound
    tight = SchedulerPolicy(moe_capacity=MoECapacity(
        n_experts=8, top_k=2, capacity_factor=8.0, skew=12.0))
    toks_tight, st_tight, dt_tight = run(tight)
    assert toks_open == toks_tight, "capacity bound changed tokens"
    per_tok = lambda dt, st: dt * 1e6 / max(st.generated_tokens, 1)  # noqa: E731
    rows = [
        ("moe/serve_capacity_open", per_tok(dt_open, st_open),
         f"us/token;deferrals={st_open.capacity_deferrals};"
         f"decode_steps={st_open.decode_steps};"
         f"dropped={st_open.moe.as_dict()['dropped_tokens']}"),
        ("moe/serve_capacity_tight", per_tok(dt_tight, st_tight),
         f"us/token;deferrals={st_tight.capacity_deferrals};"
         f"decode_steps={st_tight.decode_steps};skew=12"),
    ]
    print(f"  serve capacity: open {st_open.decode_steps} decode steps "
          f"({st_open.capacity_deferrals} deferrals) vs tight "
          f"{st_tight.decode_steps} ({st_tight.capacity_deferrals}); "
          "tokens identical")
    return rows


def moe_rows():
    """run.py hook: ep-vs-gathered trajectory rows."""
    print("\n=== MoE: expert placement through the tick engine ===")
    rows = []
    us = {}
    for mode in ("gathered", "ep"):
        row, us[mode] = _train_row(mode)
        rows.append(row)
        print(f"  train {mode}: {us[mode] / 1e3:.1f} ms/call "
              f"({row[2]})")
    rows.append(("moe/train_ep_over_gathered",
                 0.0, f"ratio={us['ep'] / us['gathered']:.3f}"))
    rows.append(_auto_row())
    print(f"  {rows[-1][0]}: {rows[-1][2]}")
    rows += _serve_capacity_rows()
    return rows


def main():
    from repro.api import ensure_host_devices

    ensure_host_devices()
    rows = moe_rows()
    print("\n=== CSV (name,us_per_call,derived) ===")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
