"""Summarize dry-run JSONs into the EXPERIMENTS.md roofline tables, and
merge every ``BENCH_pr*.json`` artifact into one cross-PR perf
trajectory table (so the bench history is diffable in one place)."""

from __future__ import annotations

import glob
import json
import os
import re
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ARCH_ORDER = [
    "whisper-large-v3", "qwen2-moe-a2.7b", "deepseek-v3-671b",
    "jamba-v0.1-52b", "phi-3-vision-4.2b", "minitron-4b", "yi-9b",
    "phi4-mini-3.8b", "llama3.2-1b", "xlstm-1.3b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath):
    cells = {}
    for fn in os.listdir(dirpath):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(dirpath, fn)) as f:
            rec = json.load(f)
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 1e9:.1f}G"


def dominant_frac(r):
    tot = r["compute_s"] + r["memory_s"] + r["collective_s"]
    dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
    # "roofline fraction": ideal-bound time / modeled total time
    return dom / max(tot, 1e-12)


def roofline_frac(r):
    """Fraction of the step spent at the binding roof if terms overlap
    perfectly: max(terms)/sum(terms) -> 1.0 means fully bound by one roof
    (no slack); we also report useful_ratio (model flops / executed)."""
    return dominant_frac(r)


def lever(arch, shape, r):
    b = r["bottleneck"]
    if b == "compute":
        if r["useful_ratio"] < 0.72 and shape == "train_4k":
            return ("selective remat (skip re-forward of cheap ops) lifts "
                    "MODEL/HLO toward 0.75+")
        return "larger micro-batch / fuse attention into the Pallas kernel"
    if b == "memory":
        if "decode" in shape or shape == "long_500k":
            return ("KV-cache reads bound decode: quantize cache to int8 "
                    "or widen batch to amortize")
        return "smaller scheduling unit U / bf16 grad accumulators"
    if b == "collective":
        if "decode" in shape or "prefill" in shape:
            return ("weight-resident serving removes per-step FSDP "
                    "gathers (§Perf cell 2)")
        return ("larger U (fewer gathers/unit), bf16 grad reduce-scatter, "
                "gather prefetch overlap")
    return "-"


def table(cells, mesh_name):
    lines = []
    hdr = (f"| arch | shape | bytes/dev | compute s | memory s | coll s | "
           f"bound | MODEL/HLO | frac | lever to move the dominant term |")
    lines.append(hdr)
    lines.append("|" + "---|" * 10)
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            rec = cells.get((a, s))
            if rec is None:
                continue
            if rec.get("status", "").startswith("skipped"):
                lines.append(f"| {a} | {s} | — | — | — | — | skipped "
                             f"(full attention) | — | — |")
                continue
            r = rec["roofline"]
            mem = rec["memory_analysis"]["bytes_per_device"]
            eff_frac = r["compute_s"] / max(
                r["compute_s"] + r["memory_s"] + r["collective_s"], 1e-12)
            lines.append(
                f"| {a} | {s} | {fmt_bytes(mem)} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
                f"{eff_frac:.2f} | {lever(a, s, r)} |")
    return "\n".join(lines)


def load_bench_artifacts(root: str = _REPO_ROOT) -> dict:
    """{pr_label: {row_name: us_per_call}} from every BENCH_pr*.json."""
    out = {}
    for path in sorted(glob.glob(os.path.join(root, "BENCH_pr*.json"))):
        m = re.search(r"BENCH_(pr\d+)\.json$", os.path.basename(path))
        if not m:
            continue
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            continue
        if isinstance(data, dict):
            out[m.group(1)] = {
                n: rec.get("us_per_call")
                for n, rec in data.items() if isinstance(rec, dict)}
    return out


def bench_trajectory(root: str = _REPO_ROOT) -> str:
    """One markdown table: bench rows × PR artifacts, us/call cells.

    Rows keep first-appearance order (the PR that introduced a bench
    owns its slot); a ``-`` cell means that PR's artifact predates or
    dropped the row.
    """
    arts = load_bench_artifacts(root)
    if not arts:
        return "(no BENCH_pr*.json artifacts found)"
    prs = sorted(arts, key=lambda p: int(p[2:]))
    names: list[str] = []
    for pr in prs:
        for n in arts[pr]:
            if n not in names:
                names.append(n)
    lines = ["| bench row | " + " | ".join(f"{p} us" for p in prs) + " |",
             "|" + "---|" * (len(prs) + 1)]
    for n in names:
        cells = []
        for pr in prs:
            us = arts[pr].get(n)
            cells.append(f"{us:.1f}" if isinstance(us, (int, float))
                         else "-")
        lines.append(f"| {n} | " + " | ".join(cells) + " |")
    return "\n".join(lines)


def main():
    arts = load_bench_artifacts()
    if arts:
        n_rows = len({n for rows in arts.values() for n in rows})
        print(f"=== cross-PR bench trajectory ({len(arts)} artifacts, "
              f"{n_rows} rows) ===")
        print(bench_trajectory())
        print()
    if not os.path.isdir("results/dryrun_sp"):
        print("(no results/dryrun_sp — skipping roofline tables)")
        return
    sp = load("results/dryrun_sp")
    print(f"single-pod cells: {len(sp)}")
    print(table(sp, "16x16"))
    if os.path.isdir("results/dryrun_mp"):
        mp = load("results/dryrun_mp")
        ok = sum(1 for r in mp.values() if r.get("status") == "ok")
        sk = sum(1 for r in mp.values()
                 if str(r.get("status", "")).startswith("skipped"))
        print(f"\nmulti-pod (2×16×16): {ok} compiled OK + {sk} documented "
              f"skips = {ok + sk}/{len(mp)}")
    # compile-time stats
    ts = [r["compile_s"] for r in sp.values() if "compile_s" in r]
    if ts:
        print(f"\ncompile times: min {min(ts):.1f}s max {max(ts):.1f}s "
              f"mean {sum(ts) / len(ts):.1f}s")


if __name__ == "__main__":
    main()
