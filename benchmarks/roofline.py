"""Analytic roofline model for the dry-run cells.

The executor's tick scan compiles to an HLO while-loop, so XLA's
``cost_analysis()`` counts the loop *body* once — we therefore derive
FLOPs/HBM/collective bytes analytically from the schedule structure we
control exactly (tables, stage specs, shapes), and use the compiled
artifact for (a) per-device peak memory (``memory_analysis``) and (b) a
structural sanity scrape of collective instructions. Formulas below are
per device per step.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    coll_bytes: float
    model_flops: float      # 6·N_active·D (train) / 2·N_active·D (serve)
    useful_ratio: float     # model_flops / hlo-equivalent flops
    bottleneck: str
    detail: dict

    def table_row(self):
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
        }


def _param_bytes(specs, dtype_bytes=2):
    return sum(int(np.prod(s.shape)) * dtype_bytes for s in specs.values())


def _param_count(specs):
    return sum(int(np.prod(s.shape)) for s in specs.values())


def _active_stage_params(cfg, specs):
    """Parameter count actually multiplied per token (MoE: top-k+shared)."""
    total = 0
    for n, sp in specs.items():
        cnt = int(np.prod(sp.shape))
        if sp.ep or n.endswith((".e_wg", ".e_wu", ".e_wd")):
            cnt = cnt * cfg.moe.top_k // cfg.moe.n_experts
        total += cnt
    return total


def analyze_cell(rt, shape_cfg, compiled_mem_bytes: float | None = None):
    """rt: pipeline Runtime; returns Roofline."""
    cfg, rc = rt.cfg, rt.rc
    D = rt.dsize
    pods = rt.pods
    kind = shape_cfg.kind
    s = shape_cfg.seq_len
    gb = shape_cfg.global_batch
    chips = pods * D * rt.geo.model_ranks
    dtype_b = 2  # bf16

    det = {}
    flops = 0.0
    hbm = 0.0
    coll = 0.0
    n_active_total = 0
    n_total = 0

    for seg in rt.geo.segments:
        specs = rt.stage_specs[seg.name]
        S = rt.geo.seg_stages(seg)
        V, Pe = seg.vpp, rt.Pe
        seq = cfg.encdec.enc_ctx if seg.name == "enc" else s
        if kind == "decode":
            seq_tok = 1 if seg.name != "enc" else 0  # enc cached
        elif kind == "prefill" and seg.name == "dec":
            seq_tok = min(seq, 448)
        else:
            seq_tok = seq
        if seq_tok == 0:
            continue

        stage_p = _param_count(specs)
        stage_act = _active_stage_params(cfg, specs)
        n_total += stage_p * S
        # model flops with this segment's *effective* token count
        seg_tokens = gb * seq_tok if gb >= pods * D else seq_tok * pods * D
        n_active_total += stage_act * S * seg_tokens

        # per-data-shard tokens processed by each pipeline group rank:
        # every model rank computes V stages for its group's micro-batches.
        # tiny global batches (long-context decode) replicate over data.
        per_shard = gb // (pods * D) if gb >= pods * D else gb
        tok_rank = max(per_shard // rt.G, 1) * seq_tok
        # attention quadratic term (causal ≈ 1/2)
        mixers = sum(1 for kd in seg.kinds
                     if kd.split(":")[0] in ("attn", "mla", "dec", "enc"))
        if kind == "decode":
            attn_f = 4 * s * cfg.n_heads * cfg.head_dim * mixers  # per tok
        else:
            attn_f = 2 * seq_tok * cfg.n_heads * cfg.head_dim * mixers
        # F + B(remat+dx) + W  = 4× GEMM fwd, 3× attention fwd-equivalents
        gemm_mult = 4.0 if kind == "train" else 1.0
        attn_mult = 3.0 if kind == "train" else 1.0
        f_gemm = 2 * stage_act * tok_rank
        f_attn = attn_f * tok_rank
        flops += V * (gemm_mult * f_gemm + attn_mult * f_attn)

        # HBM traffic: params streamed per task touch + activations
        d_model_b = cfg.d_model * dtype_b
        act_b = tok_rank * d_model_b
        n_units = max(1, -(-rt.rc.microbatches // rt.rc.unit_size)) \
            if kind == "train" else 1
        tasks = (3 if kind == "train" else 1) * rc.microbatches * V
        stage_bytes = stage_p * dtype_b / D  # sharded resident reads
        gathered_reads = tasks * _active_stage_params(cfg, specs) * dtype_b
        hbm += gathered_reads + tasks * 8 * act_b  # acts in/out + stash rw
        if kind == "decode":
            # KV/state cache rows are each read once per stage pass
            cache_b = _cache_bytes(cfg, rc, seg, gb // max(pods * D, 1)
                                   if gb >= pods * D else gb, s, D)
            hbm += V * cache_b

        # collectives: FSDP gathers/reduces cover only the *gatherable*
        # (non-EP) parameters — EP expert grads are local by construction.
        gath_p = sum(
            int(np.prod(sp.shape)) for n, sp in specs.items()
            if not (sp.ep and rt.ep))
        rs_b = {"float32": 4, "bfloat16": 2}.get(rc.grad_rs_dtype, 4)
        if kind == "train":
            gathers = n_units * (2 * V - 1)
            coll += gathers * gath_p * dtype_b * (D - 1) / D
            coll += n_units * V * gath_p * rs_b * (D - 1) / D  # grad RS
        elif not rc.serve_resident:
            coll += V * gath_p * dtype_b * (D - 1) / D       # one gather
        # wires: 2 permutes per tick ≈ 2 × (3BV ticks) × mb act bytes
        mb_act = (tok_rank // rc.microbatches) * d_model_b
        ticks = (3 if kind == "train" else 1) * rc.microbatches * V + 2 * Pe
        coll += 2 * ticks * mb_act
        # EP all-to-all per MoE layer per F/B task
        if rt.ep and cfg.moe:
            moe_layers = sum(1 for kd in seg.kinds if kd.endswith(":moe"))
            a2a = (tok_rank * cfg.moe.top_k * d_model_b
                   * (2 if kind == "train" else 1) * 2)  # dispatch+combine
            coll += moe_layers * V * a2a * (D - 1) / D

    # loss / embedding collectives (train)
    if kind == "train":
        n_tok_shard = gb // (pods * D) * s
        coll += 3 * n_tok_shard * cfg.d_model * 4  # h gather + dh psum
        if rt.multi_pod:
            coll += n_total * 4 / D  # pod grad psum (sharded residents)

    # n_active_total already folds in per-segment token counts
    model_flops = (6.0 if kind == "train" else 2.0) * n_active_total / chips
    # add io (embed/head) flops to the useful side implicitly via ratio
    r = Roofline(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=hbm / HBM_BW,
        collective_s=coll / ICI_BW,
        flops=flops, hbm_bytes=hbm, coll_bytes=coll,
        model_flops=model_flops,
        useful_ratio=model_flops / max(flops, 1.0),
        bottleneck="",
        detail=det,
    )
    terms = {"compute": r.compute_s, "memory": r.memory_s,
             "collective": r.collective_s}
    r.bottleneck = max(terms, key=terms.get)
    return r


def _cache_bytes(cfg, rc, seg, b, s, D):
    from repro.models import model as M

    total = 0
    for j, kd in enumerate(seg.kinds):
        cs = M.layer_cache_spec(cfg, rc, kd, max(b, 1), s)
        for n, spec in cs.items():
            nbytes = int(np.prod(spec.shape)) * spec.dtype.itemsize
            if b == 0:
                nbytes = 0
            total += nbytes
    # seq-sharded caches (500k): each rank reads its shard
    if b == 1 and s >= 100_000:
        total = total // D
    return total
