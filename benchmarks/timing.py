"""Benchmark-side alias of :mod:`repro.timing`.

The canonical implementation lives in ``src/repro/timing.py`` (core code
— the ``auto_profiled`` plan search — must not import the ``benchmarks``
package); this shim lets every benchmark driver share the same
warmup-discard + median-of-N discipline via a local import.
"""

from repro.timing import Timing, measure, measure_us  # noqa: F401
