"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) after the
human-readable tables, and writes the same rows as machine-readable JSON
(``BENCH_pr4.json`` by default) so the perf trajectory is tracked across
PRs. Roofline terms for the dry-run cells live in results/dryrun_*
(produced by repro.launch.dryrun) and are summarized by
benchmarks/summarize.py.
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks import timing

# anchor the default artifact to the repo root: a CWD-relative default
# scattered the JSON wherever the harness happened to run from, so the
# cross-PR bench trajectory never actually accumulated in the repo.
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json",
                    default=os.path.join(_REPO_ROOT, "BENCH_pr10.json"),
                    help="machine-readable rows artifact ('' to skip)")
    ap.add_argument("--hillclimb-budget-s", type=float, default=240.0,
                    help="wall-clock budget for the joint knob hillclimb "
                         "rows (0 to skip)")
    args = ap.parse_args()

    # the device-backed cells (serving, comm) need the fake-device flag
    # set before the first backend touch (kernel_bench initializes it)
    from repro.api import ensure_host_devices
    ensure_host_devices()

    from benchmarks import comm_bench
    from benchmarks import moe_bench
    from benchmarks import paper_tables as T
    from benchmarks import serving_bench

    rows = []
    rows += T.table2()
    rows += T.table3()
    rows += T.table5_fig5()
    rows += T.fig6()
    rows += T.fig7()
    rows += T.autogen_bench()
    rows += kernel_bench()
    rows += serving_bench.serving_rows()
    rows += serving_bench.paged_prefix_rows()
    rows += serving_bench.decode_attention_rows()
    rows += serving_bench.router_rows()
    rows += comm_bench.bench_rows()
    rows += moe_bench.moe_rows()
    if args.hillclimb_budget_s > 0:
        from benchmarks import hillclimb
        rows += hillclimb.hillclimb_rows(
            budget_s=args.hillclimb_budget_s)

    print("\n=== CSV (name,us_per_call,derived) ===")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    if args.json:
        payload = {name: {"us_per_call": us, "derived": derived}
                   for name, us, derived in rows}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json} ({len(payload)} rows)")


def kernel_bench():
    """Pallas kernels: CPU-interpret timing is meaningless for TPU perf —
    report oracle (ref) wall time per call and kernel flop accounting."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref

    rows = []
    print("\n=== kernels (ref-path CPU timing + flop accounting) ===")
    b, s, h, g, e = 1, 1024, 8, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, e), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, g, e), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, g, e), jnp.float32)
    f = jax.jit(lambda q, k, v: ref.attention(q, k, v, causal=True))
    dt = timing.measure_us(lambda: f(q, k, v), warmup=1, iters=3) / 1e6
    flops = 4 * s * s * h * e * b / 2
    rows.append(("kernel/flash_attention_ref", dt * 1e6,
                 f"flops={flops:.3e}"))
    print(f"  attention b{b} s{s} h{h}: {dt * 1e3:.1f} ms/call "
          f"({flops / dt / 1e9:.1f} GFLOP/s CPU)")

    d, n = 512, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (b, s, d))
    dt_in = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                              (b, s, d)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (d, n)) * 0.3)
    B = jax.random.normal(jax.random.PRNGKey(3), (b, s, n))
    C = jax.random.normal(jax.random.PRNGKey(4), (b, s, n))
    D = jax.random.normal(jax.random.PRNGKey(5), (d,))
    f2 = jax.jit(lambda *a: ref.selective_scan(*a))
    dt = timing.measure_us(lambda: f2(x, dt_in, A, B, C, D),
                           warmup=1, iters=3) / 1e6
    rows.append(("kernel/selective_scan_ref", dt * 1e6, f"d={d} n={n}"))
    print(f"  selective_scan s{s} d{d}: {dt * 1e3:.1f} ms/call")

    nn, dd, vv = 2048, 512, 32000
    hh = jax.random.normal(jax.random.PRNGKey(0), (nn, dd)) * 0.3
    ww = jax.random.normal(jax.random.PRNGKey(1), (dd, vv)) * 0.05
    lab = jax.random.randint(jax.random.PRNGKey(2), (nn,), 0, vv)
    f3 = jax.jit(lambda *a: ref.softmax_xent(*a)[0])
    dt = timing.measure_us(lambda: f3(hh, ww, lab),
                           warmup=1, iters=3) / 1e6
    rows.append(("kernel/fused_xent_ref", dt * 1e6, f"vocab={vv}"))
    print(f"  fused_xent n{nn} vocab{vv}: {dt * 1e3:.1f} ms/call")
    return rows


if __name__ == "__main__":
    main()
