"""Reproductions of the paper's tables/figures via the schedule simulator.

One function per paper artifact; each returns a list of CSV rows
(name, us_per_call, derived) per the harness contract, plus prints a
human-readable table. The cost model mirrors the paper's testbed (A800,
NVLink intra-node + IB inter-node) at a 50% GEMM MFU assumption.
"""

from __future__ import annotations

import numpy as np

from repro.api import SchedParams, generate_schedule, get_arch
from repro.core import analysis
from repro.core.autogen import autogen
from repro.core.simulator import (
    A800,
    CostModel,
    TPU_V5E,
    cost_model_for,
    simulate,
)


def _gpt_cost(size: str, *, P: int, V: int, dp: int, seq: int = 1024,
              mbs: int = 1, split: bool, remat: bool = False,
              cross_node_dp: bool = False, hw=A800):
    """Cost model matching the paper's setting: no activation
    recomputation (their Table 2 memory model), A800 GEMM rates."""
    cfg = get_arch("gpt_paper").config(size)
    d, L = cfg.d_model, cfg.n_layers
    layer_flops = 2 * (12 * d * d) * seq * mbs + 2 * seq * seq * d * mbs
    act_bytes = seq * mbs * d * 2
    layers_per_stage = L / (P * V)
    stage_param_bytes = 12 * d * d * layers_per_stage * 2
    cm = cost_model_for(
        hw, layer_flops_f=layer_flops, layers_per_stage=layers_per_stage,
        act_bytes=act_bytes, stage_param_bytes=stage_param_bytes, dp=dp,
        remat=remat, cross_node_dp=cross_node_dp)
    # full per-layer activation footprint (no remat): ~60×seq×d bytes
    # covers hidden states, attention internals and fp32 softmax temps —
    # calibrated so GPipe's 1.5B/B=32 lands near the paper's 53 GB.
    m_act_layer = 60 * seq * mbs * d * 2
    cm = CostModel(
        t_f=cm.t_f,
        t_b=cm.t_b if split else cm.t_b + cm.t_w,
        t_w=cm.t_w if split else 0.0,
        t_p2p=cm.t_p2p, t_gather=cm.t_gather, t_reduce=cm.t_reduce,
        m_act=m_act_layer * layers_per_stage,
        m_wstash=(2 * act_bytes * layers_per_stage) if split else 0.0,
        m_weight=cm.m_weight,
    )
    return cfg, cm


def _ddp_allreduce_s(size: str, hw=A800, cross=False) -> float:
    """Full-gradient ring all-reduce each step (DDP baselines)."""
    cfg = get_arch("gpt_paper").config(size)
    d, L = cfg.d_model, cfg.n_layers
    grad_bytes = 12 * d * d * L * 2
    bw = hw.link_bw if cross else hw.intra_bw
    return 2 * grad_bytes / bw


METHODS = [
    # (label, method, V, split_bw, fsdp)
    ("GPipe", "gpipe", 1, False, False),
    ("1F1B", "1f1b", 1, False, False),
    ("Interleaved 1F1B", "interleaved", 2, False, False),
    ("FS-BFSPP", "bfs", 2, False, True),
    ("ZeroPP-Best", "zeropp", 2, True, True),
    ("ZeroPP-S", "zeropp", 2, True, True),
]


def table3(sizes=("1.5B", "6.2B", "14.6B"), micro=(8, 16, 32), P=4, dp=4):
    """Paper Table 3: samples/GPU/s + peak memory across methods."""
    rows = []
    print(f"\n=== Table 3 reproduction (P={P}, DP={dp}, A800 cost model) ===")
    print(f"{'model':7s} {'B':>3s} " + "".join(f"{m[0]:>18s}" for m in METHODS))
    for size in sizes:
        for B in micro:
            line = f"{size:7s} {B:3d} "
            for label, method, V, split, fsdp in METHODS:
                cfg, cm = _gpt_cost(size, P=P, V=V, dp=dp, split=split)
                if label == "ZeroPP-Best":
                    # best U that still fits in HBM (paper semantics)
                    best = r2 = None
                    for U in sorted({B, 16, 8, 4}, reverse=True):
                        if U > B:
                            continue
                        tt = generate_schedule(method, SchedParams(
                            P=P, V=V, n_mb=B, split_bw=split, unit=U))
                        r2 = simulate(tt, cm)
                        if r2.peak_mem / 1e9 <= 80.0 and (
                                best is None
                                or r2.makespan < best.makespan):
                            best = r2
                    res = best or r2
                else:
                    U = min(B, 8)
                    sp = SchedParams(P=P, V=V, n_mb=B, split_bw=split,
                                     unit=U if method == "zeropp" else B)
                    tt = generate_schedule(method, sp)
                    if not fsdp:
                        tt.gather = None
                        tt.reduce = None
                    res = simulate(tt, cm)
                makespan = res.makespan
                # DDP baselines pay a full-gradient allreduce at step end
                if not fsdp:
                    makespan += _ddp_allreduce_s(size)
                # samples/iter = dp·B over dp·P GPUs
                thpt_gpu = B / (makespan * P)
                mem_gb = res.peak_mem / 1e9
                oom = mem_gb > 80.0
                rows.append((f"table3/{size}/B{B}/{label}",
                             makespan * 1e6 / B,
                             f"thpt={thpt_gpu:.3f}sps mem={mem_gb:.1f}GB"
                             + (" OOM" if oom else "")))
                cell = "OOM" if oom else f"{thpt_gpu:6.3f}/{mem_gb:5.1f}G"
                line += f"   {cell:>15s}"
            print(line)
    return rows


def table5_fig5(size="6.2B", B=32, P=4, V=2, dp=4):
    """Fig 5 / Table 5: scheduling-unit size U trade-off."""
    rows = []
    print(f"\n=== Fig 5 (U sweep, {size}, B={B}) ===")
    for U in (2, 4, 7, 8, 16, 32):
        cfg, cm = _gpt_cost(size, P=P, V=V, dp=dp, split=True)
        tt = generate_schedule("zeropp", SchedParams(P=P, V=V, n_mb=B, unit=U))
        res = simulate(tt, cm)
        print(f"  U={U:3d}  makespan={res.makespan:8.4f}s "
              f"bubble={res.bubble_frac:.3f} mem={res.peak_mem / 1e9:6.2f}GB"
              f" gathers={res.n_gather}")
        rows.append((f"fig5/U{U}", res.makespan * 1e6,
                     f"bubble={res.bubble_frac:.3f}"
                     f" mem={res.peak_mem / 1e9:.2f}GB"))
    return rows


def fig6(size="14.6B", B=16, P=4, dp=4):
    """Fig 6: interleaved stages per device V."""
    rows = []
    print(f"\n=== Fig 6 (V sweep, {size}) ===")
    for V in (1, 2, 3, 4):
        cfg, cm = _gpt_cost(size, P=P, V=V, dp=dp, split=True)
        tt = generate_schedule("zeropp", SchedParams(P=P, V=V, n_mb=B, unit=B))
        res = simulate(tt, cm)
        print(f"  V={V}  makespan={res.makespan:8.4f}s "
              f"bubble={res.bubble_frac:.3f} "
              f"gathers/unit={res.n_gather}")
        rows.append((f"fig6/V{V}", res.makespan * 1e6,
                     f"bubble={res.bubble_frac:.3f}"))
    return rows


def fig7(size="6.2B", global_samples=64, P=4):
    """Fig 7: FSDP size and cross-node sharding."""
    rows = []
    print(f"\n=== Fig 7 (FSDP size sweep, {size}, {global_samples} samples"
          " global) ===")
    for dp, cross in ((2, False), (4, False), (8, True), (16, True)):
        B = max(global_samples // dp, 1)
        cfg, cm = _gpt_cost(size, P=P, V=2, dp=dp, split=True,
                            cross_node_dp=cross)
        tt = generate_schedule("zeropp", SchedParams(P=P, V=2, n_mb=B,
                                            unit=min(B, 2 * P - 1)))
        res = simulate(tt, cm)
        thpt = global_samples / res.makespan / (P * dp)
        print(f"  DP={dp:3d} cross_node={str(cross):5s} "
              f"makespan={res.makespan:8.4f}s "
              f"samples/gpu/s={thpt:7.3f}")
        rows.append((f"fig7/dp{dp}", res.makespan * 1e6,
                     f"sps_gpu={thpt:.3f} cross={cross}"))
    return rows


def table2(P=4, V=2, B=16, D=4, L=32):
    """Table 2: closed forms vs simulator-measured quantities."""
    rows = []
    print(f"\n=== Table 2 (closed forms, P={P} V={V} B={B} D={D} L={L}) ===")
    print(f"{'method':14s} {'bubbles':>9s} {'weight':>8s} {'act':>8s} "
          f"{'#comm':>8s}")
    for m in ("gpipe", "1f1b", "fs-1f1b", "interleaved", "bfs", "fs-bfs",
              "zeropp", "fs-zeropp"):
        a = analysis.analyze(m, L=L, P=P, V=V if "1f1b" != m and
                             m != "gpipe" else 1, B=B, U=2 * P - 1, D=D)
        print(f"{m:14s} {a.bubble_units:9.2f} {a.weight_mem:8.2f} "
              f"{a.act_mem:8.2f} {a.n_param_comm:8.2f}")
        rows.append((f"table2/{m}", 0.0,
                     f"bub={a.bubble_units:.2f} wmem={a.weight_mem:.2f} "
                     f"amem={a.act_mem:.2f} comm={a.n_param_comm:.2f}"))
    return rows


def autogen_bench(P=4, V=2, B=8, U=4):
    """§4 heuristic vs greedy W-fill, plus the full plan selection."""
    from repro.core.plan import PlanAnalysis, select_plan

    rows = []
    cfg, cm = _gpt_cost("6.2B", P=P, V=V, dp=4, split=True)
    res = autogen(SchedParams(P=P, V=V, n_mb=B), cm)
    greedy = simulate(generate_schedule("zeropp", SchedParams(P=P, V=V, n_mb=B)), cm)
    print(f"\n=== §4 auto-generation (P={P} V={V} B={B}) ===")
    print(f"  postponed-W start: {res.makespan_before:.4f}s")
    print(f"  after heuristic:   {res.makespan_after:.4f}s "
          f"({res.n_insertions} insertions)")
    print("  trajectory:        " + " -> ".join(
        f"{m:.4f}" for m in res.makespans))
    print(f"  greedy W-fill:     {greedy.makespan:.4f}s")
    rows.append(("autogen/before", res.makespan_before * 1e6, ""))
    rows.append(("autogen/after", res.makespan_after * 1e6,
                 f"insertions={res.n_insertions}"))
    rows.append(("autogen/greedy", greedy.makespan * 1e6, ""))

    # unit-gated §4: W postponement confined to each unit's live window,
    # so stash depth stays U and peak memory drops vs full-depth autogen
    # (the makespan/memory trade-off select_plan ranks on).
    sim_full = simulate(res.table, cm)
    gated = autogen(SchedParams(P=P, V=V, n_mb=B, unit=U), cm,
                    unit_gated=True)
    sim_g = simulate(gated.table, cm)
    print(f"  gated (U={U}):     {sim_g.makespan:.4f}s "
          f"({gated.n_insertions} insertions, "
          f"mem {sim_g.peak_mem / 1e9:.2f}GB vs "
          f"{sim_full.peak_mem / 1e9:.2f}GB full-depth, "
          f"rs_exposed {sim_g.rs_exposed * 1e6:.1f}us)")
    rows.append(("autogen_gated/makespan", sim_g.makespan * 1e6,
                 f"U={U} insertions={gated.n_insertions}"))
    rows.append(("autogen_gated/peak_mem_gb", sim_g.peak_mem / 1e9,
                 f"full_depth_gb={sim_full.peak_mem / 1e9:.3f}"))
    rows.append(("autogen/peak_mem_gb", sim_full.peak_mem / 1e9, ""))

    # the schedule="auto" selection over every registered schedule,
    # costed with the same 6.2B A800 model — what a session would pick
    sel = select_plan(P, V, B, B, cm, preset="a800")
    print(f"  auto selection:    {sel.selected.name} "
          f"({sel.analysis.makespan:.4f}s)")
    for n, a in sorted(sel.candidates.items(),
                       key=lambda kv: (not isinstance(kv[1], PlanAnalysis),
                                       getattr(kv[1], 'makespan', 0))):
        if isinstance(a, PlanAnalysis):
            rows.append((f"auto/{n}", a.makespan * 1e6,
                         "selected" if n == sel.selected.name else ""))
    return rows
