"""Continuous vs static batching on the llama3.2-1b CPU demo.

Same session, same jitted steps, same skewed-length workload — the only
variable is the admission policy: ``static`` admits a full batch only
when the pool is idle (every slot waits for the batch's longest request),
``continuous`` reclaims and refills each slot the tick its request
finishes. Reported per the harness CSV contract
(``name,us_per_call,derived``): wall-clock tok/s, mean slot occupancy
over decode ticks, and decode-step counts.

Run directly (``PYTHONPATH=src:. python -m benchmarks.serving_bench``)
or via ``benchmarks/run.py``.
"""

from __future__ import annotations

import time

from repro.api import ensure_host_devices


def _workload(vocab: int, n: int, seed: int = 0):
    """Skewed request lengths: a few long stragglers among short ones —
    the regime where static batching strands slots."""
    import numpy as np

    rng = np.random.RandomState(seed)
    work = []
    for i in range(n):
        p = int(rng.randint(3, 10))
        g = 12 if i % 4 == 0 else int(rng.randint(2, 5))  # skew
        work.append((rng.randint(0, vocab, size=p).astype(np.int32), g))
    return work


def _drive(sess, params, work, mode: str):
    from repro.serving import SchedulerPolicy

    eng = sess.serve_engine(
        params, policy=SchedulerPolicy(mode=mode, max_prefills_per_tick=4))
    t0 = time.time()
    handles = [eng.submit(toks, max_gen=g) for toks, g in work]
    eng.run_until_idle()
    dt = time.time() - t0
    for h in handles:
        h.result(timeout=0)  # all finished
    return eng.stats, dt


def serving_rows(n_requests: int = 16, slots: int = 4, seed: int = 0):
    ensure_host_devices()
    import jax

    from repro.api import session

    sess = session("llama3.2-1b", mode="serve", data=2, max_slots=slots,
                   max_seq=24, overrides=dict(microbatches=2))
    params = sess.init_params(jax.random.PRNGKey(0))
    work = _workload(sess.cfg.vocab, n_requests, seed)

    # warm the jit caches on the full workload (every distinct prompt
    # width compiles once) so neither timed mode pays compile time
    _drive(sess, params, work, "continuous")

    rows = []
    print("\n=== serving: continuous vs static batching "
          f"({n_requests} skewed requests, {slots} slots) ===")
    results = {}
    for mode in ("static", "continuous"):
        st, dt = _drive(sess, params, work, mode)
        tok_s = st.generated_tokens / max(dt, 1e-9)
        results[mode] = (st, dt, tok_s)
        per_step = dt / max(st.decode_steps + st.prefill_steps, 1)
        rows.append((f"serving/{mode}_batching", per_step * 1e6,
                     f"tok_s={tok_s:.2f};occupancy={st.occupancy:.3f};"
                     f"decode_steps={st.decode_steps}"))
        print(f"  {mode:11s}: {st.generated_tokens} tokens in {dt:.3f}s "
              f"({tok_s:.1f} tok/s), occupancy {st.occupancy:.2f}, "
              f"{st.decode_steps} decode + {st.prefill_steps} prefill "
              f"steps")
    speedup = results["continuous"][2] / max(results["static"][2], 1e-9)
    rows.append(("serving/continuous_speedup", 0.0,
                 f"x={speedup:.3f}"))
    print(f"  continuous/static tok/s: {speedup:.2f}x")
    return rows


def paged_prefix_rows(n_requests: int = 8, sys_prompt: int = 256,
                      tail: int = 8, page_size: int = 16,
                      max_gen: int = 4, seed: int = 0):
    """Shared-system-prompt workload: every request repeats the same
    ``sys_prompt`` tokens, then diverges into a private ``tail``.

    The contiguous cache recomputes the prompt per request (8 x 264
    prefill tokens); the paged radix prefills the shared prefix once per
    data shard at most — the first request computes it, same-shard
    followers ref the pages, cross-shard followers get device page
    copies — so prefill work collapses to the unique tokens. Reported:
    prompt tokens actually computed, the reduction factor, and the page
    high-water mark vs the contiguous slot footprint.
    """
    ensure_host_devices()
    import jax
    import numpy as np

    from repro.api import session

    rng = np.random.RandomState(seed)
    need = sys_prompt + tail + max_gen
    max_seq = -(-need // page_size) * page_size
    sys_toks = None
    work = []

    rows = []
    print("\n=== serving: paged KV + radix prefix sharing "
          f"({n_requests} requests x {sys_prompt}-token shared system "
          f"prompt, page_size {page_size}) ===")
    stats = {}
    for name, paged in (("contiguous", False), ("paged", True)):
        kw = dict(page_size=page_size) if paged else {}
        sess = session("llama3.2-1b", mode="serve", data=2, max_slots=4,
                       max_seq=max_seq, prefill_chunk=64,
                       overrides=dict(microbatches=2), **kw)
        if sys_toks is None:
            vocab = sess.cfg.vocab
            sys_toks = rng.randint(0, vocab, size=sys_prompt
                                   ).astype(np.int32)
            work = [np.concatenate(
                [sys_toks,
                 rng.randint(0, vocab, size=tail).astype(np.int32)])
                for _ in range(n_requests)]
        params = sess.init_params(jax.random.PRNGKey(0))
        eng = sess.serve_engine(params)
        t0 = time.time()
        handles = [eng.submit(toks, max_gen=max_gen) for toks in work]
        eng.run_until_idle()
        dt = time.time() - t0
        for h in handles:
            h.result(timeout=0)
        st = eng.stats
        stats[name] = st
        if paged:
            footprint = sess.max_slots * sess.pages_per_slot
            derived = (f"prefill_tokens={st.prefill_tokens};"
                       f"prefix_hits={st.prefix_hits};"
                       f"peak_pages={st.peak_pages_in_use};"
                       f"footprint_pages={footprint}")
            print(f"  paged      : {st.prefill_tokens} prefill tokens, "
                  f"{st.prefix_hits} prefix hits "
                  f"({st.prefix_hit_tokens} cached tokens), peak "
                  f"{st.peak_pages_in_use}/{footprint} pages, {dt:.3f}s")
        else:
            derived = f"prefill_tokens={st.prefill_tokens}"
            print(f"  contiguous : {st.prefill_tokens} prefill tokens, "
                  f"{dt:.3f}s")
        rows.append((f"serving/prefix_{name}", dt * 1e6, derived))
    reduction = stats["contiguous"].prefill_tokens \
        / max(stats["paged"].prefill_tokens, 1)
    rows.append(("serving/prefix_prefill_reduction", 0.0,
                 f"x={reduction:.3f}"))
    print(f"  prefill-token reduction: {reduction:.2f}x "
          f"(issue bar: >= 4x)")
    return rows


def _cache_bytes(sess) -> int:
    import numpy as np

    total = 0
    for leaf in __import__("jax").tree_util.tree_leaves(
            sess.init_caches(abstract=True)):
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total


def decode_attention_rows(n_requests: int = 8, prompt: int = 4,
                          max_gen: int = 16, page_size: int = 8,
                          seed: int = 0):
    """Decode-attention throughput: contiguous rows vs paged pools, fp32
    vs int8 pages.

    A decode-heavy workload (short prompts, long generations) so the
    timed region is dominated by the cached-attention step the slot-aware
    kernel owns. Reported per variant: decode tok/s, per-decode-step
    latency, and the KV-cache footprint in bytes (the int8 rows carry
    the per-page scale leaves in their total — the memory the quantized
    pages actually cost, not just the pools).
    """
    ensure_host_devices()
    import jax
    import numpy as np

    from repro.api import session

    rng = np.random.RandomState(seed)
    need = prompt + max_gen + 1
    max_seq = -(-need // page_size) * page_size

    variants = [
        ("contiguous_fp32", dict(kv_cache_dtype="fp32")),
        ("paged_fp32", dict(page_size=page_size, kv_cache_dtype="fp32")),
        ("paged_int8", dict(page_size=page_size, kv_cache_dtype="int8")),
    ]
    rows = []
    print("\n=== serving: decode attention — contiguous vs paged, fp32 "
          f"vs int8 pages ({n_requests} requests x {max_gen} decode "
          f"tokens, page_size {page_size}) ===")
    work = None
    tok_s_by = {}
    for name, kw in variants:
        sess = session("llama3.2-1b", mode="serve", data=2, max_slots=4,
                       max_seq=max_seq, overrides=dict(microbatches=2),
                       **kw)
        if work is None:
            vocab = sess.cfg.vocab
            work = [(rng.randint(0, vocab, size=prompt).astype(np.int32),
                     max_gen) for _ in range(n_requests)]
        params = sess.init_params(jax.random.PRNGKey(0))
        cache_b = _cache_bytes(sess)
        _drive(sess, params, work, "continuous")   # warm the jit caches
        st, dt = _drive(sess, params, work, "continuous")
        tok_s = st.generated_tokens / max(dt, 1e-9)
        tok_s_by[name] = tok_s
        per_step = dt / max(st.decode_steps + st.prefill_steps, 1)
        rows.append((f"serving/decode_{name}", per_step * 1e6,
                     f"tok_s={tok_s:.2f};cache_bytes={cache_b};"
                     f"decode_steps={st.decode_steps}"))
        print(f"  {name:15s}: {st.generated_tokens} tokens in {dt:.3f}s "
              f"({tok_s:.1f} tok/s), cache {cache_b / 1e6:.2f} MB")
    shrink = None
    for r in rows:
        if r[0].endswith("paged_fp32"):
            fp_b = int(r[2].split("cache_bytes=")[1].split(";")[0])
        if r[0].endswith("paged_int8"):
            q_b = int(r[2].split("cache_bytes=")[1].split(";")[0])
    shrink = fp_b / max(q_b, 1)
    rows.append(("serving/decode_int8_cache_shrink", 0.0,
                 f"x={shrink:.3f}"))
    print(f"  int8 page-pool shrink vs fp32: {shrink:.2f}x "
          f"(scales included)")
    return rows


def router_rows(n_requests: int = 32, slots: int = 4, seed: int = 0):
    """Data-parallel serving tier: aggregate tok/s at 1/2/4 engine
    replicas behind :class:`EngineRouter` (each replica its own session
    + pools), plus the single-engine elastic reshard pause.

    The deployment the router targets is one replica per host, so the
    aggregate wall-clock is the *slowest replica's* drain — on this
    single-process simulation each replica's drain is timed
    independently and the aggregate is total tokens / max(per-replica
    time). (Driving the replicas threaded in one process would just
    serialize them on the CPU backend and measure GIL contention, not
    the tier.) Dispatch balance is the router's real contribution here:
    least-outstanding-tokens keeps the per-replica drain times — and so
    the aggregate — flat under the skewed workload.

    The reshard row parks a loaded engine, rebuilds it on a data-halved
    topology and re-admits — its pause includes the shrunk mesh's jit
    compile (the cold-restart cost a real elastic event pays)."""
    ensure_host_devices()
    import jax

    from repro.api import session
    from repro.runtime.topology import Topology
    from repro.serving import EngineRouter

    def make_engine():
        sess = session("llama3.2-1b", mode="serve",
                       topology=Topology(kind="fake_cpu", data=2),
                       max_slots=slots, max_seq=24,
                       overrides=dict(microbatches=2))
        params = sess.init_params(jax.random.PRNGKey(0))
        return sess.serve_engine(params)

    rows = []
    print(f"\n=== serving: EngineRouter replicas ({n_requests} skewed "
          f"requests, {slots} slots/replica, one replica per host) ===")
    work = None
    tok_s_by = {}
    for n_rep in (1, 2, 4):
        engines = [make_engine() for _ in range(n_rep)]
        if work is None:
            work = _workload(engines[0].session.cfg.vocab, n_requests,
                             seed)
        router = EngineRouter(engines)
        # warm every replica's jit cache outside the timed region
        for toks, g in work:
            router.submit(toks, max_gen=g)
        router.run_until_idle()
        handles = [router.submit(toks, max_gen=g) for toks, g in work]
        per = []
        for i in router.alive():        # one replica per host: drains
            t0 = time.time()            # run concurrently in wall-clock
            engines[i].run_until_idle()
            per.append(time.time() - t0)
        wall = max(per)
        for h in handles:
            h.result(timeout=0)
        router.close()
        st = router.stats()
        tokens = sum(len(h.tokens) for h in handles)
        tok_s = tokens / max(wall, 1e-9)
        tok_s_by[n_rep] = tok_s
        dispatched = [p["dispatched"] for p in st["per_replica"]]
        rows.append((f"serving/router_{n_rep}_replicas", wall * 1e6,
                     f"tok_s={tok_s:.2f};dispatched={dispatched};"
                     f"per_replica_s={[round(p, 3) for p in per]}"))
        print(f"  {n_rep} replica{'s' if n_rep > 1 else ' '}: {tokens} "
              f"tokens, slowest replica {wall:.3f}s ({tok_s:.1f} tok/s "
              f"aggregate, dispatched {dispatched})")
    speedup = tok_s_by[2] / max(tok_s_by[1], 1e-9)
    rows.append(("serving/router_2x_speedup", 0.0, f"x={speedup:.3f}"))
    print(f"  2-replica aggregate vs 1: {speedup:.2f}x "
          f"(issue bar: > 1x)")

    eng = make_engine()
    hs = [eng.submit(toks, max_gen=g) for toks, g in work]
    eng.step()
    eng.step()
    r = eng.reshard(Topology(kind="fake_cpu", data=1))
    eng.run_until_idle()
    for h in hs:
        h.result(timeout=0)
    rows.append(("serving/reshard_pause", r["pause_s"] * 1e6,
                 f"parked={r['parked']};incl_compile=1"))
    print(f"  reshard data 2->1: parked {r['parked']} requests, "
          f"pause {r['pause_s']:.3f}s (incl. shrunk-mesh compile); all "
          f"{len(hs)} streams completed")
    return rows


def main():
    rows = (serving_rows() + paged_prefix_rows()
            + decode_attention_rows() + router_rows())
    print("\n=== CSV (name,us_per_call,derived) ===")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
