"""Continuous vs static batching on the llama3.2-1b CPU demo.

Same session, same jitted steps, same skewed-length workload — the only
variable is the admission policy: ``static`` admits a full batch only
when the pool is idle (every slot waits for the batch's longest request),
``continuous`` reclaims and refills each slot the tick its request
finishes. Reported per the harness CSV contract
(``name,us_per_call,derived``): wall-clock tok/s, mean slot occupancy
over decode ticks, and decode-step counts.

Run directly (``PYTHONPATH=src:. python -m benchmarks.serving_bench``)
or via ``benchmarks/run.py``.
"""

from __future__ import annotations

import time

from repro.api import ensure_host_devices


def _workload(vocab: int, n: int, seed: int = 0):
    """Skewed request lengths: a few long stragglers among short ones —
    the regime where static batching strands slots."""
    import numpy as np

    rng = np.random.RandomState(seed)
    work = []
    for i in range(n):
        p = int(rng.randint(3, 10))
        g = 12 if i % 4 == 0 else int(rng.randint(2, 5))  # skew
        work.append((rng.randint(0, vocab, size=p).astype(np.int32), g))
    return work


def _drive(sess, params, work, mode: str):
    from repro.serving import SchedulerPolicy

    eng = sess.serve_engine(
        params, policy=SchedulerPolicy(mode=mode, max_prefills_per_tick=4))
    t0 = time.time()
    handles = [eng.submit(toks, max_gen=g) for toks, g in work]
    eng.run_until_idle()
    dt = time.time() - t0
    for h in handles:
        h.result(timeout=0)  # all finished
    return eng.stats, dt


def serving_rows(n_requests: int = 16, slots: int = 4, seed: int = 0):
    ensure_host_devices()
    import jax

    from repro.api import session

    sess = session("llama3.2-1b", mode="serve", data=2, max_slots=slots,
                   max_seq=24, overrides=dict(microbatches=2))
    params = sess.init_params(jax.random.PRNGKey(0))
    work = _workload(sess.cfg.vocab, n_requests, seed)

    # warm the jit caches on the full workload (every distinct prompt
    # width compiles once) so neither timed mode pays compile time
    _drive(sess, params, work, "continuous")

    rows = []
    print("\n=== serving: continuous vs static batching "
          f"({n_requests} skewed requests, {slots} slots) ===")
    results = {}
    for mode in ("static", "continuous"):
        st, dt = _drive(sess, params, work, mode)
        tok_s = st.generated_tokens / max(dt, 1e-9)
        results[mode] = (st, dt, tok_s)
        per_step = dt / max(st.decode_steps + st.prefill_steps, 1)
        rows.append((f"serving/{mode}_batching", per_step * 1e6,
                     f"tok_s={tok_s:.2f};occupancy={st.occupancy:.3f};"
                     f"decode_steps={st.decode_steps}"))
        print(f"  {mode:11s}: {st.generated_tokens} tokens in {dt:.3f}s "
              f"({tok_s:.1f} tok/s), occupancy {st.occupancy:.2f}, "
              f"{st.decode_steps} decode + {st.prefill_steps} prefill "
              f"steps")
    speedup = results["continuous"][2] / max(results["static"][2], 1e-9)
    rows.append(("serving/continuous_speedup", 0.0,
                 f"x={speedup:.3f}"))
    print(f"  continuous/static tok/s: {speedup:.2f}x")
    return rows


def main():
    rows = serving_rows()
    print("\n=== CSV (name,us_per_call,derived) ===")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
