"""Joint knob hillclimb: greedy coordinate descent over the schedule
knobs that exist but are hand-set.

The ZeroPP efficiency claim rests on picking the right point in
(U, V, schedule family, ``gather_prefetch``, ``coalesce``,
``grad_compress``, ``mem_budget``) for a given machine —
``schedule="auto"`` only searches the schedule axis under a *derived*
cost model. This driver climbs the whole knob vector against *measured*
steps: one axis at a time, try every alternative value with the rest
fixed, move to the best measured improvement, repeat until a full sweep
makes no move (or the wall-clock budget runs out).

Every measurement goes through the shared ``benchmarks/timing.py``
discipline (warmup + median-of-3 real train steps) and is recorded in
the persisted plan cache (``core/plan_cache.py`` ``measurements``
section, keyed by knob vector + code salt) — an interrupted climb
resumes from cache, paying only for points it has not timed yet.
``mem_budget`` participates as a feasibility gate: a point whose
*simulated* peak memory exceeds the budget is rejected without being
measured (exactly how the paper discards U values that don't fit HBM).

``hillclimb_rows`` emits harness-contract rows (trajectory: one row per
evaluated point with its knob vector, plus the best point and the
profiled-vs-derived selection delta) into ``BENCH_pr8.json`` via
``benchmarks/run.py``.

Run standalone:
  SPMD_DEVICES=8 PYTHONPATH=src:. python -m benchmarks.hillclimb \
      [--arch llama3.2-1b] [--budget-s 240] [--mem-budget BYTES]
"""

from __future__ import annotations

import argparse
import json
import time

from benchmarks import timing

#: Axis order for the coordinate descent. Schedule family first (the
#: coarsest lever), then the §3.1 unit depth, then the overlap/layout
#: knobs. unit=0 means "full depth" (U = microbatches).
KNOB_AXES = (
    ("schedule", ("zeropp", "autogen_gated", "autogen", "1f1b", "bfs")),
    ("unit", (0, 2, 1)),
    ("vpp", (1, 2)),
    ("gather_prefetch", (0, 1, 2)),
    ("coalesce", ("flat", "none")),
    ("grad_compress", ("none", "int8")),
    # expert placement (MoE archs only; the axis is skipped for dense
    # models): gathered = experts ride the FSDP collectives, ep =
    # experts sharded over data + all-to-all token movement
    ("moe_mode", ("gathered", "ep")),
)

#: Relative improvement a move must show to be accepted — absorbs the
#: residual noise the median-of-3 doesn't (CPU runners jitter a few %).
MIN_GAIN = 0.03


def _start_vector(arch: str) -> dict:
    """The hand-set defaults the repo ships — the climb's origin."""
    from repro.api import get_arch

    _, rc = get_arch(arch).reduced()
    return {
        "schedule": rc.schedule,
        "unit": rc.unit,
        "vpp": rc.vpp,
        "gather_prefetch": rc.gather_prefetch,
        "coalesce": rc.coalesce,
        "grad_compress": rc.grad_compress,
        "moe_mode": rc.moe_mode,
    }


def _vec_label(vec: dict) -> str:
    return (f"{vec['schedule']}-U{vec['unit']}-V{vec['vpp']}"
            f"-pf{vec['gather_prefetch']}-{vec['coalesce']}"
            f"-gc{vec['grad_compress']}-{vec['moe_mode']}")


class Climber:
    """Measured evaluation of knob vectors for one (arch × shape) cell,
    cache-backed so repeated/resumed climbs skip known points."""

    def __init__(self, arch: str, *, data: int = 2, seq: int = 32,
                 microbatches: int = 4, mem_budget: float | None = None,
                 iters: int = 3):
        self.arch, self.data, self.seq = arch, data, seq
        self.microbatches = microbatches
        self.mem_budget = mem_budget
        self.iters = iters
        self.evals = 0          # fresh measurements this run
        self.cache_hits = 0     # points answered from the persisted cache

    def _cache_key(self, vec: dict) -> str:
        from repro.core import plan_cache

        return "hillclimb|" + plan_cache.entry_key(
            (self.arch, self.seq, self.data, self.microbatches)
            + tuple(vec[k] for k, _ in KNOB_AXES))

    def evaluate(self, vec: dict) -> tuple[float | None, str]:
        """(median us/call, detail) — us None when infeasible/failed."""
        from repro.core import plan_cache

        key = self._cache_key(vec)
        hit = plan_cache.load_measurement(key)
        if isinstance(hit, dict) and "us" in hit:
            self.cache_hits += 1
            us = hit["us"]
            return (us if us is not None else None,
                    hit.get("detail", "") + ";cached")
        us, detail = self._measure(vec)
        self.evals += 1
        plan_cache.store_measurement(key, {"us": us, "detail": detail})
        return us, detail

    def _measure(self, vec: dict):
        import jax

        from repro.api import SessionError, session

        try:
            sess = session(
                self.arch, mode="train", data=self.data, seq_len=self.seq,
                overrides=dict(microbatches=self.microbatches, **vec))
            sched = sess.describe()["schedule"]
            if self.mem_budget is not None \
                    and sched["peak_mem"] > self.mem_budget:
                return None, (f"over_budget:peak_mem={sched['peak_mem']:.3e}"
                              f">{self.mem_budget:.3e}")
            params = sess.init_params(jax.random.PRNGKey(0))
            batch = sess.stream(seed=0).batch(0)
            step = sess.train_step_fn()
            us = timing.measure_us(lambda: step(params, batch),
                                   warmup=1, iters=self.iters)
            return us, f"peak_mem={sched['peak_mem']:.3e}"
        except (SessionError, ValueError, AssertionError) as e:
            return None, f"infeasible: {e}"
        except Exception as e:  # noqa: BLE001 — record, keep climbing
            return None, f"failed: {type(e).__name__}: {e}"


def climb(arch: str = "llama3.2-1b", *, budget_s: float = 240.0,
          data: int = 2, seq: int = 32, microbatches: int = 4,
          mem_budget: float | None = None, max_sweeps: int = 4):
    """Greedy coordinate descent; returns (best_vec, best_us, rows).

    ``rows`` follow the harness contract (name, us_per_call, derived):
    one per evaluated point — sweep number, knob vector and whether it
    became the incumbent — so the full trajectory lands in the JSON
    artifact, not just the winner.
    """
    cl = Climber(arch, data=data, seq=seq, microbatches=microbatches,
                 mem_budget=mem_budget)
    from repro.api import get_arch
    has_moe = get_arch(arch).reduced()[0].moe is not None
    t0 = time.perf_counter()

    def out_of_budget() -> bool:
        return time.perf_counter() - t0 >= budget_s

    rows = []
    n_eval = 0

    def record(sweep, vec, us, detail, tag):
        nonlocal n_eval
        n_eval += 1
        rows.append((
            f"hillclimb/{n_eval:02d}_{_vec_label(vec)}",
            us if us is not None else -1.0,
            f"sweep={sweep};{tag};{detail};vector="
            + json.dumps(vec, sort_keys=True)))

    vec = _start_vector(arch)
    vec["unit"] = vec["unit"] if vec["unit"] else 2   # climb from U=2
    best_us, detail = cl.evaluate(vec)
    if best_us is None:
        raise RuntimeError(
            f"hillclimb start point infeasible for {arch}: {detail}")
    record(0, vec, best_us, detail, "start")
    print(f"[hillclimb] start {_vec_label(vec)}: {best_us / 1e3:.1f} "
          f"ms/call")

    sweep = 0
    moved = True
    while moved and sweep < max_sweeps and not out_of_budget():
        sweep += 1
        moved = False
        for knob, values in KNOB_AXES:
            if knob == "moe_mode" and not has_moe:
                continue
            if out_of_budget():
                print(f"[hillclimb] budget ({budget_s:.0f}s) exhausted "
                      f"mid-sweep {sweep}")
                break
            axis_best = None   # (us, value)
            for val in values:
                if val == vec[knob]:
                    continue
                cand = dict(vec, **{knob: val})
                us, detail = cl.evaluate(cand)
                tag = f"try:{knob}={val}"
                record(sweep, cand, us, detail, tag)
                if us is None:
                    print(f"[hillclimb]  {_vec_label(cand)}: skipped "
                          f"({detail.split(';')[0]})")
                    continue
                print(f"[hillclimb]  {_vec_label(cand)}: "
                      f"{us / 1e3:.1f} ms/call")
                if axis_best is None or us < axis_best[0]:
                    axis_best = (us, val)
                if out_of_budget():
                    break
            if axis_best is not None \
                    and axis_best[0] < best_us * (1 - MIN_GAIN):
                best_us, _ = axis_best
                vec = dict(vec, **{knob: axis_best[1]})
                moved = True
                print(f"[hillclimb] move -> {_vec_label(vec)} "
                      f"({best_us / 1e3:.1f} ms/call)")
    rows.append((f"hillclimb/best_{_vec_label(vec)}", best_us,
                 f"sweeps={sweep};evals={cl.evals};"
                 f"cache_hits={cl.cache_hits};vector="
                 + json.dumps(vec, sort_keys=True)))
    print(f"[hillclimb] best {_vec_label(vec)}: {best_us / 1e3:.1f} "
          f"ms/call ({cl.evals} measured, {cl.cache_hits} from cache, "
          f"{time.perf_counter() - t0:.0f}s)")
    return vec, best_us, rows


def profiled_vs_derived_rows(arch: str = "llama3.2-1b", *, data: int = 2,
                             seq: int = 32, microbatches: int = 4,
                             unit: int = 2, top_k: int = 3,
                             budget_s: float | None = None):
    """The selection-delta rows: what ``auto_profiled`` picked vs what
    the purely simulated ``auto`` ranking would have picked, both in
    *measured* us/call (the acceptance number for the coarse→fine
    search: selected ≤ simulated-best, ties allowed)."""
    from repro.api import session

    sess = session(arch, mode="train", data=data, seq_len=seq,
                   schedule="auto_profiled", profile_top_k=top_k,
                   profile_budget_s=budget_s,
                   overrides=dict(microbatches=microbatches, unit=unit))
    sel = sess.plan_selection
    prof = sel.profile or {}
    measured = sel.measured or {}
    win = sel.selected.name
    win_us = measured.get(win)
    sim_best = prof.get("simulated_best")
    sim_us = prof.get("simulated_best_us")
    rows = [(f"auto_profiled/selected_{win}", win_us or -1.0,
             f"provenance={sel.provenance};source={sess._plan_source}")]
    if sim_best is not None:
        rows.append((f"auto_profiled/simulated_best_{sim_best}",
                     sim_us if sim_us is not None else -1.0,
                     "the plan schedule='auto' would pick"))
    if win_us is not None and sim_us:
        delta = (sim_us - win_us) / sim_us
        rows.append(("auto_profiled/selection_delta", 0.0,
                     f"pct={delta:.1%};selected={win};"
                     f"simulated_best={sim_best}"))
        print(f"[auto_profiled] selected {win} ({win_us / 1e3:.1f} ms) "
              f"vs simulated-best {sim_best} ({sim_us / 1e3:.1f} ms): "
              f"{delta:+.1%}")
    return rows


def hillclimb_rows(budget_s: float = 240.0, arch: str = "llama3.2-1b"):
    """run.py hook: trajectory + best + selection-delta rows."""
    from repro.api import ensure_host_devices

    ensure_host_devices()
    _, _, rows = climb(arch, budget_s=budget_s)
    rows += profiled_vs_derived_rows(arch)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--budget-s", type=float, default=240.0)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--mem-budget", type=float, default=None,
                    help="simulated peak-mem feasibility gate (bytes)")
    ap.add_argument("--json", default=None,
                    help="write the trajectory rows to this JSON file")
    args = ap.parse_args()

    from repro.api import ensure_host_devices
    ensure_host_devices()

    _, _, rows = climb(args.arch, budget_s=args.budget_s, data=args.data,
                       seq=args.seq, microbatches=args.microbatches,
                       mem_budget=args.mem_budget)
    rows += profiled_vs_derived_rows(args.arch, data=args.data,
                                     seq=args.seq,
                                     microbatches=args.microbatches)
    print("\n=== CSV (name,us_per_call,derived) ===")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({n: {"us_per_call": us, "derived": d}
                       for n, us, d in rows}, f, indent=2, sort_keys=True)
        print(f"wrote {args.json} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
