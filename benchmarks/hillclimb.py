import os

from repro.api import ensure_host_devices, session

ensure_host_devices(512, force=True)

"""§Perf hillclimbing driver: hypothesis → change → re-lower → re-analyse.

Runs a named sequence of RunConfig variants for one (arch × shape) cell on
the production mesh, recording for each: per-device memory (compiled
memory_analysis), the three roofline terms and the dominant one. Results
append to results/hillclimb.jsonl; EXPERIMENTS.md §Perf narrates them.

  PYTHONPATH=src:. python -m benchmarks.hillclimb --cell deepseek_train
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

from repro.models.common import SHAPES  # noqa: E402


def measure(arch, shape, rc_overrides, label):
    import benchmarks.roofline as RL

    shape_cfg = SHAPES[shape]
    sess = session(arch, mode="dry-run", shape=shape, reduced=False,
                   overrides=rc_overrides)
    t0 = time.time()
    compiled = sess.lower().compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    roof = RL.analyze_cell(sess.rt, shape_cfg)
    rec = {
        "cell": f"{arch}×{shape}", "label": label,
        "overrides": {k: str(v) for k, v in rc_overrides.items()},
        "mem_gb": round(mem.temp_size_in_bytes / 1e9, 2),
        "compute_s": round(roof.compute_s, 4),
        "memory_s": round(roof.memory_s, 4),
        "collective_s": round(roof.collective_s, 4),
        "bottleneck": roof.bottleneck,
        "useful_ratio": round(roof.useful_ratio, 3),
        "compile_s": round(dt, 1),
    }
    dom = max(roof.compute_s, roof.memory_s, roof.collective_s)
    rec["dominant_s"] = round(dom, 4)
    rec["step_s_lower_bound"] = rec["dominant_s"]
    print(f"[{label:28s}] mem={rec['mem_gb']:7.2f}G "
          f"C={rec['compute_s']:.3f} M={rec['memory_s']:.3f} "
          f"X={rec['collective_s']:.3f} dom={rec['bottleneck'][:4]} "
          f"({rec['dominant_s']:.3f}s)")
    os.makedirs("results", exist_ok=True)
    with open("results/hillclimb.jsonl", "a") as f:
        f.write(json.dumps(rec) + "\n")
    return rec


CELLS = {
    # Cell 1: deepseek train — worst memory, collective-heavy, most
    # paper-representative (FSDP×PP interplay is the paper's subject).
    "deepseek_train": [
        ("deepseek-v3-671b", "train_4k", {}, "baseline U=16 (paper dflt)"),
        ("deepseek-v3-671b", "train_4k", {"unit": 8}, "U=8 (unit memory)"),
        ("deepseek-v3-671b", "train_4k", {"unit": 4}, "U=4"),
        ("deepseek-v3-671b", "train_4k", {"unit": 2}, "U=2"),
        ("deepseek-v3-671b", "train_4k",
         {"unit": 4, "grad_rs_dtype": "bfloat16"}, "U=4 + bf16 grad-RS"),
        ("deepseek-v3-671b", "train_4k",
         {"unit": 4, "grad_rs_dtype": "bfloat16", "vpp": 2},
         "U=4 + bf16-RS + V=2"),
        ("deepseek-v3-671b", "train_4k",
         {"unit": 2, "grad_rs_dtype": "bfloat16", "vocab_chunk": 2048},
         "U=2 + bf16-RS + loss-chunk-2k"),
        ("deepseek-v3-671b", "train_4k",
         {"unit": 2, "grad_rs_dtype": "bfloat16", "vocab_chunk": 2048,
          "attn_block_k": 1024}, "…+ attn block 1k"),
        ("deepseek-v3-671b", "train_4k",
         {"unit": 4, "grad_rs_dtype": "bfloat16",
          "no_defer_extra": (".mix.wuq", ".mix.wuk", ".mix.wuv",
                             ".mix.wo")},
         "U=4 + partial W-deferral"),
        ("deepseek-v3-671b", "train_4k",
         {"unit": 2, "grad_rs_dtype": "bfloat16",
          "no_defer_extra": (".mix.",)},
         "U=2 + attn dW all in B"),
    ],
    # Cell 2: deepseek decode — most collective-bound cell in the table.
    "deepseek_decode": [
        ("deepseek-v3-671b", "decode_32k", {}, "baseline (FSDP gathers)"),
        ("deepseek-v3-671b", "decode_32k", {"serve_resident": True},
         "weight-resident serving"),
        ("deepseek-v3-671b", "decode_32k",
         {"serve_resident": True, "microbatches": 4},
         "resident + 4 microbatches"),
        ("deepseek-v3-671b", "decode_32k",
         {"serve_resident": True, "microbatches": 16},
         "resident + 16 microbatches"),
    ],
    # Cell 3: llama train — clean dense cell; drive to HBM-feasible at
    # minimal throughput cost with the paper's own U lever.
    "llama_train": [
        ("llama3.2-1b", "train_4k", {}, "baseline U=16"),
        ("llama3.2-1b", "train_4k", {"unit": 8}, "U=8"),
        ("llama3.2-1b", "train_4k", {"unit": 4}, "U=4"),
        ("llama3.2-1b", "train_4k",
         {"unit": 8, "grad_rs_dtype": "bfloat16"}, "U=8 + bf16 grad-RS"),
        ("llama3.2-1b", "train_4k",
         {"unit": 8, "grad_rs_dtype": "bfloat16", "schedule": "bfs"},
         "bfs schedule (ablation)"),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    args = ap.parse_args()
    for arch, shape, ovr, label in CELLS[args.cell]:
        try:
            measure(arch, shape, ovr, label)
        except Exception as e:  # noqa: BLE001
            print(f"[{label}] FAILED: {e}")


if __name__ == "__main__":
    main()
