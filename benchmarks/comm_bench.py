"""Coalesced flat-segment collectives benchmark (A/B + calibration).

Measures the ISSUE-4 claim end-to-end on fake CPU devices:

  * **collapse** — compiled-HLO collective *sites* in the train step under
    ``coalesce="flat"`` vs ``"none"``: per-tensor gathering emits one
    all-gather / reduce-scatter per gatherable tensor inside the tick
    scan body, the flat layout exactly one of each — O(#tensors) → O(1)
    per stage segment per tick;
  * **parity** — one train step under both modes must produce
    bit-identical gradients and metrics (the layout only changes the wire
    format, never the math);
  * **ranking** — ``schedule="auto"`` under the calibrated ``a800``
    preset, i.e. the §4 selection with α–β collective costs
    (per-tick collective count × launch latency + bytes × 1/bandwidth);
  * **--calibrate** — re-derive the α–β constants from the hardware
    presets (launch latency + effective bandwidth) and gate the literals
    recorded in ``repro.core.plan.COLLECTIVE_ALPHA_BETA`` against them
    (25% drift fails), then report the per-cell α-term share over a
    ``benchmarks/roofline.py`` byte-accounting grid — the latency
    fraction per-tensor collectives pay and the flat layout removes.

Run: ``SPMD_DEVICES=8 PYTHONPATH=src:. python -m benchmarks.comm_bench
[--json comm_bench.json] [--calibrate]``.  Prints the harness CSV
contract (``name,us_per_call,derived``) and writes the same rows as a
machine-readable JSON artifact for CI.
"""

from __future__ import annotations

import argparse
import json
import re

from benchmarks import timing
from repro.api import ensure_host_devices

ARCH = "llama3.2-1b"

#: Per-collective launch latencies (s) — published small-message
#: latencies for each preset's DP interconnect; the α source.
LAUNCH_LATENCY = {"a800": 8.0e-06, "tpu_v5e": 1.2e-06}
#: Effective link efficiency applied to the preset peak bandwidth.
LINK_EFFICIENCY = 0.9
#: a2a launch-latency multiple over the base collective: expert dispatch
#: is a pairwise exchange (send + receive setup on every peer) — the
#: ``<preset>:a2a`` α literals in COLLECTIVE_ALPHA_BETA are 2× base.
A2A_LATENCY_FACTOR = 2.0


def _collective_sites(hlo_text: str) -> dict:
    """Count collective instruction sites in compiled HLO text."""
    out = {}
    for op in ("all-gather", "reduce-scatter", "all-reduce",
               "collective-permute"):
        # matches both `op(` applications and async `op-start(` forms
        out[op] = len(re.findall(rf"\b{op}(?:-start)?\(", hlo_text))
    return out


def _session(mode: str, **extra):
    from repro.api import session

    return session(ARCH, seq_len=16, coalesce=mode,
                   overrides=dict(microbatches=4, unit=2), **extra)


def bench_rows(json_path: str | None = None):
    """The A/B cell: HLO collective sites, step timing, bitwise parity,
    and the calibrated-preset auto ranking. Returns harness CSV rows."""
    ensure_host_devices()
    import jax
    import numpy as np

    rows = []
    sites = {}
    grads = {}
    metrics = {}
    step_us = {}
    n_tensors = None
    print("=== flat-segment coalescing (A/B on fake CPU devices) ===")
    for mode in ("flat", "none"):
        sess = _session(mode)
        rt = sess.rt
        n_tensors = len(rt.gatherable["main"])
        if mode == "flat":
            assert rt.flat_layouts["main"] is not None
        params = sess.init_params(jax.random.PRNGKey(0))
        batch = sess.stream().batch(0)
        # one AOT compile serves both the HLO scrape and the timed calls
        # (train_step_fn() would retrace + recompile the same program)
        step = sess.train_step_fn().lower(params, batch).compile()
        sites[mode] = _collective_sites(step.as_text())
        g, m = step(params, batch)
        jax.block_until_ready(g)
        # shared timing discipline (warmup above, median-of-3): single
        # wall-clock shots flip flat/none rankings on noisy CPU runners
        step_us[mode] = timing.measure_us(
            lambda: step(params, batch), warmup=0, iters=3)
        grads[mode] = jax.device_get(g)
        metrics[mode] = jax.device_get(m)
        print(f"  {mode:>4}: all-gather sites={sites[mode]['all-gather']:3d}"
              f" reduce-scatter sites={sites[mode]['reduce-scatter']:3d}"
              f" step={step_us[mode] / 1e3:.1f} ms")

    # collapse: per-tensor emits >= n_tensors gather sites in the scan
    # body; flat collapses the body to one of each.
    ag_f, ag_n = sites["flat"]["all-gather"], sites["none"]["all-gather"]
    rs_f, rs_n = (sites["flat"]["reduce-scatter"],
                  sites["none"]["reduce-scatter"])
    assert n_tensors and n_tensors > 1
    assert ag_n - ag_f >= n_tensors - 1, (
        f"expected the flat layout to remove >= {n_tensors - 1} "
        f"all-gather sites, got {ag_n} -> {ag_f}")
    assert rs_n > rs_f, (rs_n, rs_f)
    print(f"  collapse: {n_tensors} gatherable tensors -> "
          f"all-gather sites {ag_n} -> {ag_f}, "
          f"reduce-scatter {rs_n} -> {rs_f}")

    # parity: bit-identical grads + metrics
    flat_g = dict(jax.tree_util.tree_flatten_with_path(grads["flat"])[0])
    n_cmp = 0
    for kp, vn in jax.tree_util.tree_flatten_with_path(grads["none"])[0]:
        assert np.array_equal(np.asarray(vn), np.asarray(flat_g[kp])), (
            f"flat/none grads differ at {jax.tree_util.keystr(kp)}")
        n_cmp += 1
    for k in metrics["none"]:
        assert np.array_equal(np.asarray(metrics["none"][k]),
                              np.asarray(metrics["flat"][k])), k
    print(f"  parity: {n_cmp} grad tensors bit-identical")

    rows += [
        ("comm/allgather_sites_flat", float(ag_f),
         f"n_tensors={n_tensors}"),
        ("comm/allgather_sites_none", float(ag_n),
         f"n_tensors={n_tensors}"),
        ("comm/reducescatter_sites_flat", float(rs_f), ""),
        ("comm/reducescatter_sites_none", float(rs_n), ""),
        ("comm/train_step_flat", step_us["flat"], "us_per_step"),
        ("comm/train_step_none", step_us["none"], "us_per_step"),
        ("comm/grad_parity_tensors", float(n_cmp), "bit_identical=1"),
    ]

    # schedule="auto" ranking under the calibrated a800 α–β preset
    sess_auto = _session("flat", schedule="auto", cost_preset="a800")
    d = sess_auto.describe()
    auto = d["schedule"]["auto"]
    coll = d["schedule"]["collectives"]
    print(f"  auto(a800): selected={auto['selected']} "
          f"alpha={coll['alpha_s']:.1e}s "
          f"per_gather_tick={coll['per_gather_tick']}")
    ranked = sorted(
        ((n, m) for n, m in auto["candidates"].items()
         if isinstance(m, float)), key=lambda x: x[1])
    for i, (name, mk) in enumerate(ranked):
        mark = " <- selected" if name == auto["selected"] else ""
        print(f"    {i + 1}. {name:<12} makespan={mk:.3e}{mark}")
        rows.append((f"comm/auto_rank_{name}", mk * 1e6,
                     f"rank={i + 1}"))

    if json_path:
        payload = {n: {"us_per_call": us, "derived": der}
                   for n, us, der in rows}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"  wrote {json_path} ({len(rows)} rows)")
    return rows


# --------------------------------------------------------------------------- #
# α–β calibration against the roofline terms
# --------------------------------------------------------------------------- #


def derive_alpha_beta(preset: str) -> tuple[float, float]:
    """(α, β) derived from the preset hardware constants: α is the
    published small-message launch latency of the preset's DP
    interconnect (``LAUNCH_LATENCY``), β the inverse effective bandwidth
    (peak intra-node/link bandwidth × ``LINK_EFFICIENCY``). These are the
    source of the ``COLLECTIVE_ALPHA_BETA`` literals in core/plan.py —
    the drift gate below fires if either side is edited without the
    other (e.g. a Hardware preset bandwidth change).

    ``<preset>:a2a`` entries derive from the same base hardware with the
    launch latency scaled by ``A2A_LATENCY_FACTOR`` (pairwise exchange);
    β is the base inverse bandwidth unchanged."""
    from repro.core.plan import PRESETS

    base, _, kind = preset.partition(":")
    hw = PRESETS[base]
    bw_eff = (hw.intra_bw or hw.link_bw) * LINK_EFFICIENCY
    alpha = LAUNCH_LATENCY[base]
    if kind == "a2a":
        alpha *= A2A_LATENCY_FACTOR
    return alpha, 1.0 / bw_eff


def alpha_share_grid(preset: str):
    """Per-cell (n_coll, bytes, α-term share) over a schedule grid.

    Uses the ``benchmarks/roofline.py`` collective-byte accounting (the
    terms the compiled-HLO scrape validates) to show how much of each
    cell's collective time is launch latency under per-tensor
    collectives — the fraction the flat layout removes. Pure reporting;
    the α/β constants themselves come from ``derive_alpha_beta``.
    """
    ensure_host_devices()
    import dataclasses as dc

    import jax

    from benchmarks.roofline import analyze_cell
    from repro.core.pipeline import Runtime
    from repro.models import model as M
    from repro.models.common import ShapeConfig

    alpha, beta = derive_alpha_beta(preset)
    mod = M.get_arch(ARCH)
    cfg, rc0 = mod.reduced()
    samples = []
    for mb, unit in ((4, 2), (4, 4), (8, 2), (8, 4)):
        rc = dc.replace(rc0, microbatches=mb, unit=unit, coalesce="none")
        geo = M.build_geometry(cfg, rc)
        mesh = jax.make_mesh((4, geo.model_ranks), ("data", "model"))
        rt = Runtime(cfg, rc, mesh)
        pt = rt.tables["main"]
        n_tensors = len(rt.gatherable["main"])
        events = float((pt.gather_v >= 0).sum() + (pt.reduce_v >= 0).sum())
        n_coll = events / pt.Pe * n_tensors
        gb = 4 * rc.groups * rc.microbatches
        roof = analyze_cell(rt, ShapeConfig("cal", 16, gb, "train"))
        t_alpha = n_coll * alpha
        t_beta = roof.coll_bytes * beta
        samples.append({"microbatches": mb, "unit": unit,
                        "n_coll": n_coll, "coll_bytes": roof.coll_bytes,
                        "alpha_share": t_alpha / (t_alpha + t_beta)})
    return samples


def calibrate(verbose: bool = True):
    """Consistency-gate the recorded ``COLLECTIVE_ALPHA_BETA`` literals
    against the values derived from the hardware presets, and report the
    per-cell α-term share over the roofline grid."""
    from repro.core.plan import COLLECTIVE_ALPHA_BETA

    out = {}
    for preset in sorted(COLLECTIVE_ALPHA_BETA):
        alpha, beta = derive_alpha_beta(preset)
        ra, rb = COLLECTIVE_ALPHA_BETA[preset]
        drift_a = abs(alpha - ra) / ra
        drift_b = abs(beta - rb) / rb
        out[preset] = {"alpha_derived": alpha, "beta_derived": beta,
                       "alpha_recorded": ra, "beta_recorded": rb,
                       "drift_alpha": drift_a, "drift_beta": drift_b}
        if verbose:
            print(f"  {preset}: derived alpha={alpha:.3e} "
                  f"beta={beta:.3e} | recorded alpha={ra:.3e} "
                  f"beta={rb:.3e} | drift {drift_a:.1%}/{drift_b:.1%}")
    for s in alpha_share_grid("a800"):
        if verbose:
            print(f"  a800 cell mb={s['microbatches']} u={s['unit']}: "
                  f"n_coll={s['n_coll']:.0f} "
                  f"bytes={s['coll_bytes']:.2e} -> per-tensor ticks are "
                  f"{s['alpha_share']:.0%} launch latency")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="comm_bench.json",
                    help="machine-readable artifact path ('' to skip)")
    ap.add_argument("--calibrate", action="store_true",
                    help="refit the α–β constants against roofline terms")
    args = ap.parse_args()
    rows = bench_rows(json_path=args.json or None)
    if args.calibrate:
        print("=== α–β calibration (roofline terms) ===")
        cal = calibrate()
        for preset, c in cal.items():
            assert c["drift_alpha"] < 0.25 and c["drift_beta"] < 0.25, (
                f"{preset}: recorded COLLECTIVE_ALPHA_BETA drifted "
                f">=25% from the fit — re-record the constants in "
                f"repro/core/plan.py: {c}")
    print("\n=== CSV (name,us_per_call,derived) ===")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    print("COMM_BENCH_OK")


if __name__ == "__main__":
    main()
